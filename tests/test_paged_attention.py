"""`paged_attention` kernel-op conformance (DESIGN.md §Paging / §Kernels):
every registered backend against the einsum reference, the einsum backend
against the dense ragged decode attention under an identity block table,
registry capability routing (interpret on any platform, pallas TPU-gated,
auto -> einsum off-TPU), kv_len edge cases, and the MULTI-TOKEN verify
window (DESIGN.md §Speculation): q_len in {1, 2, 5, 9} on every backend
against a per-row single-query loop, with W == 1 bitwise-identical to the
historical single-query semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PEFTConfig
from repro.kernels import api as kernel_api
from repro.kernels import paged_attention as pa
from repro.models import attention as attn_mod


def _case(seed, B=3, H=8, K=2, dh=16, n_pages=14, ps=4, pps=6, W=1):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, W, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, K, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, K, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, n_pages, size=(B, pps)), jnp.int32)
    # ragged: row j of the window reads kv_len + j rows, so the deepest
    # read (kv_len + W - 1) must stay inside the block-table window
    kv_len = jnp.asarray(rng.integers(1, pps * ps - W + 2, size=(B,)),
                         jnp.int32)
    return q, kp, vp, bt, kv_len


class TestConformance:
    @pytest.mark.parametrize("seed", range(4))
    def test_interpret_matches_einsum(self, seed):
        q, kp, vp, bt, kv_len = _case(seed)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_mha_no_gqa_groups(self):
        """K == H (G = 1) exercises the degenerate group reshape."""
        q, kp, vp, bt, kv_len = _case(7, H=4, K=4)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_kv_len_edges(self):
        """kv_len = 1 (a freshly reset slot) and kv_len = full window."""
        q, kp, vp, bt, _ = _case(11)
        pps, ps = bt.shape[1], kp.shape[1]
        kv_len = jnp.asarray([1, pps * ps, ps], jnp.int32)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        assert not np.isnan(np.asarray(out)).any()

    def test_einsum_equals_dense_ragged_attention(self):
        """Identity block table over a pool that IS the dense cache laid
        out page by page: paged einsum == direct_attention bit-for-bit
        (fp32) — the exactness anchor the runtime acceptance rests on."""
        rng = np.random.default_rng(3)
        B, H, K, dh, ps, pps = 2, 4, 2, 8, 4, 5
        max_len = pps * ps
        ck = jnp.asarray(rng.normal(size=(B, max_len, K, dh)), jnp.float32)
        cv = jnp.asarray(rng.normal(size=(B, max_len, K, dh)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(B, 1, H, dh)), jnp.float32)
        kv_len = jnp.asarray([7, 18], jnp.int32)
        # pool: page b*pps + p holds row-block p of batch row b
        kp = ck.reshape(B * pps, ps, K, dh)
        vp = cv.reshape(B * pps, ps, K, dh)
        bt = jnp.arange(B * pps, dtype=jnp.int32).reshape(B, pps)
        ref = attn_mod.direct_attention(q, ck, cv, causal=False,
                                        kv_len=kv_len)
        out = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


class TestWindowedConformance:
    """Multi-token verify window (DESIGN.md §Speculation): query row j
    attends pool positions < kv_len + j. The ground truth is the ALREADY
    PROVEN single-query op run once per row — the windowed op must be the
    batched equivalent of that loop on every backend."""

    @staticmethod
    def _rowwise_reference(q, kp, vp, bt, kv_len):
        W = q.shape[1]
        rows = [pa.paged_attention_einsum(q[:, j:j + 1], kp, vp, bt,
                                          kv_len + j)
                for j in range(W)]
        return jnp.concatenate(rows, axis=1)

    @pytest.mark.parametrize("W", [1, 2, 5, 9])
    def test_einsum_matches_rowwise_single_query(self, W):
        q, kp, vp, bt, kv_len = _case(21 + W, W=W)
        ref = self._rowwise_reference(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("W", [1, 2, 5, 9])
    def test_interpret_matches_einsum(self, W):
        q, kp, vp, bt, kv_len = _case(31 + W, W=W)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_w1_bitwise_identical_to_single_query(self):
        """W == 1 is not merely close to the old semantics — the einsum
        path must be the SAME computation (bitwise), so wiring verify
        through the windowed op cannot perturb plain decode."""
        q, kp, vp, bt, kv_len = _case(17)
        single = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        ref = self._rowwise_reference(q, kp, vp, bt, kv_len)
        np.testing.assert_array_equal(np.asarray(single), np.asarray(ref))

    def test_ragged_kv_len_and_window_edges(self):
        """Extremes: a slot one token past reset (kv_len=1) and a slot
        whose window's deepest row reads the full block-table span."""
        W = 5
        q, kp, vp, bt, _ = _case(41, W=W)
        pps, ps = bt.shape[1], kp.shape[1]
        kv_len = jnp.asarray([1, pps * ps - W + 1, ps], jnp.int32)
        ref = self._rowwise_reference(q, kp, vp, bt, kv_len)
        for fn in (pa.paged_attention_einsum,
                   lambda *a: pa.paged_attention_pallas(*a, interpret=True)):
            out = fn(q, kp, vp, bt, kv_len)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5, rtol=1e-5)
            assert not np.isnan(np.asarray(out)).any()

    def test_windowed_mha_no_gqa_groups(self):
        q, kp, vp, bt, kv_len = _case(43, H=4, K=4, W=3)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


class TestRegistryRouting:
    def test_backends_registered(self):
        assert set(kernel_api.backends_for("paged_attention", pa.OWNER)) \
            == {"pallas", "interpret", "einsum"}

    def test_auto_resolves_einsum_off_tpu(self):
        op = kernel_api.resolve_op("paged_attention", pa.OWNER,
                                   PEFTConfig(), platform="cpu")
        assert op.backend == "einsum"

    def test_auto_resolves_pallas_on_tpu(self):
        op = kernel_api.resolve_op("paged_attention", pa.OWNER,
                                   PEFTConfig(), platform="tpu")
        assert op.backend == "pallas"

    def test_interpret_policy_any_platform(self):
        op = kernel_api.resolve_op(
            "paged_attention", pa.OWNER,
            PEFTConfig(kernel_backend="interpret"), platform="cpu")
        assert op.backend == "interpret"

    def test_resolved_ops_agree(self):
        q, kp, vp, bt, kv_len = _case(5)
        outs = {}
        for backend in ("einsum", "interpret"):
            op = kernel_api.resolve_op("paged_attention", pa.OWNER,
                                       PEFTConfig(kernel_backend=backend))
            outs[backend] = np.asarray(op.fn(q, kp, vp, bt, kv_len))
        np.testing.assert_allclose(outs["interpret"], outs["einsum"],
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas path needs a TPU")
class TestCompiledTPU:
    def test_pallas_matches_einsum(self):
        q, kp, vp, bt, kv_len = _case(0, dh=128, ps=8)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-2, rtol=2e-2)

    @pytest.mark.parametrize("W", [2, 5])
    def test_pallas_windowed_matches_einsum(self, W):
        q, kp, vp, bt, kv_len = _case(1, dh=128, ps=8, W=W)
        ref = pa.paged_attention_einsum(q, kp, vp, bt, kv_len)
        out = pa.paged_attention_pallas(q, kp, vp, bt, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-2, rtol=2e-2)
