"""Data pipeline: determinism, seekability, sharding consistency, learnable
structure, and the Appendix C.2 synthetic classification dataset."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticClassification, SyntheticLM


class TestSyntheticLM:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10000), st.integers(0, 3))
    def test_step_keyed_determinism(self, step, seed):
        a = SyntheticLM(vocab=32, batch=4, seq=8, seed=seed).batch_at(step)
        b = SyntheticLM(vocab=32, batch=4, seq=8, seed=seed).batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_next_tokens(self):
        b = SyntheticLM(vocab=32, batch=2, seq=16).batch_at(0)
        assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)

    def test_distinct_steps_differ(self):
        d = SyntheticLM(vocab=32, batch=4, seq=16)
        assert not np.array_equal(d.batch_at(0)["tokens"],
                                  d.batch_at(1)["tokens"])

    def test_markov_structure_is_learnable(self):
        """Bigram statistics of the stream match the teacher's transition
        distribution better than uniform (i.e., there is signal to learn)."""
        d = SyntheticLM(vocab=8, batch=64, seq=64, task_seed=5)
        toks = np.asarray(d.batch_at(0)["tokens"])
        counts = np.zeros((8, 8))
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                counts[a, b] += 1
        emp = counts / np.maximum(counts.sum(1, keepdims=True), 1)
        table = jax.nn.softmax(
            np.asarray(jax.device_get(
                __import__("repro.data.synthetic", fromlist=["markov_table"])
                .markov_table(8, 5))), axis=-1)
        uniform = np.full((8, 8), 1 / 8)
        err_teacher = np.abs(emp - np.asarray(table)).mean()
        err_uniform = np.abs(emp - uniform).mean()
        assert err_teacher < err_uniform

    def test_codebook_expansion(self):
        b = SyntheticLM(vocab=32, batch=2, seq=8, codebooks=4).batch_at(0)
        assert b["tokens"].shape == (2, 8, 4)


class TestSyntheticClassification:
    def test_dataset_shapes_and_balance(self):
        x, y = SyntheticClassification(num_classes=8).dataset(32)
        assert x.shape == (256, 2) and y.shape == (256,)
        _, counts = np.unique(np.asarray(y), return_counts=True)
        assert (counts == 32).all()

    def test_separable_at_low_noise(self):
        x, y = SyntheticClassification(num_classes=4, noise=0.05).dataset(16)
        # nearest-centroid classifies perfectly at tiny noise
        x, y = np.asarray(x), np.asarray(y)
        cents = np.stack([x[y == c].mean(0) for c in range(4)])
        pred = np.argmin(((x[:, None] - cents[None]) ** 2).sum(-1), -1)
        assert (pred == y).mean() == 1.0
