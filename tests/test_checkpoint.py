"""Checkpoint/fault-tolerance tests: roundtrip, atomicity under crash, keep-k,
async manager, resume, preemption, and elastic re-shard across device counts
(subprocess with a different XLA host-device count)."""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import manager as ckpt
from repro.configs.base import PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.train import loop, step as ts


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                   "c": jnp.int32(7)},
        "tup": (jnp.zeros(3), {"d": jnp.float32(1.5)}),
    }


class TestRoundtrip:
    def test_save_restore_identity(self, tmp_path):
        t = _tree()
        ckpt.save_sync(str(tmp_path), 5, t)
        out, step = ckpt.restore(str(tmp_path))
        assert step == 5
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # structure preserved (incl tuple)
        assert isinstance(out["tup"], tuple)

    def test_bfloat16_roundtrip(self, tmp_path):
        t = {"w": jnp.array([1.5, 2.5], jnp.bfloat16)}
        ckpt.save_sync(str(tmp_path), 1, t)
        out, _ = ckpt.restore(str(tmp_path))
        assert out["w"].dtype == jnp.bfloat16

    def test_latest_selected(self, tmp_path):
        for s in (1, 3, 2):
            ckpt.save_sync(str(tmp_path), s, {"x": jnp.float32(s)})
        out, step = ckpt.restore(str(tmp_path))
        assert step == 3 and float(out["x"]) == 3.0

    def test_atomicity_no_partial_checkpoints(self, tmp_path):
        """A tmp dir left behind by a crash must be invisible to restore."""
        ckpt.save_sync(str(tmp_path), 1, {"x": jnp.float32(1)})
        fake = tmp_path / "step_00000009.tmp-crashed"
        fake.mkdir()
        (fake / "x.npy").write_bytes(b"garbage")
        assert ckpt.available_steps(str(tmp_path)) == [1]


class TestManager:
    def test_async_keep_k(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, {"x": jnp.float32(s)})
        mgr.wait()
        mgr.close()
        assert ckpt.available_steps(str(tmp_path)) == [3, 4]

    def test_error_surfaces(self, tmp_path):
        mgr = ckpt.CheckpointManager(str(tmp_path / "sub"), keep=1)
        mgr.save(0, {"x": jnp.float32(0)})
        mgr.close()  # should not raise
        assert ckpt.available_steps(str(tmp_path / "sub")) == [0]


class TestLoopFaultTolerance:
    def _setup(self):
        cfg = C.reduced(C.get("yi-6b")).replace(vocab=32)
        model = build(cfg, PEFTConfig(n=8, alpha=5.0))
        tcfg = TrainConfig(total_steps=12, warmup_steps=2)
        state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(ts.make_train_step(model, tcfg))
        data = SyntheticLM(vocab=32, batch=2, seq=8)
        return step_fn, state, frozen, data, tcfg

    def test_resume_from_checkpoint(self, tmp_path):
        step_fn, state, frozen, data, tcfg = self._setup()
        state1, rep1 = loop.run(step_fn, state, frozen, data, tcfg,
                                ckpt_dir=str(tmp_path), ckpt_every=5,
                                log_every=0, log_fn=lambda s: None)
        assert rep1.steps_run == 12
        # fresh state resumes from step 10 and runs only 2 steps
        state0, _ = ts.init_state(
            build(C.reduced(C.get("yi-6b")).replace(vocab=32),
                  PEFTConfig(n=8, alpha=5.0)), tcfg, jax.random.PRNGKey(0))
        state2, rep2 = loop.run(step_fn, state0, frozen, data, tcfg,
                                ckpt_dir=str(tmp_path), ckpt_every=5,
                                log_every=0, log_fn=lambda s: None)
        # the loop saves a final checkpoint at completion -> resume is a no-op
        assert rep2.resumed_from == 12
        assert rep2.steps_run == 0
        # drop the final checkpoint -> resume from the periodic one at 10
        import shutil
        shutil.rmtree(tmp_path / "step_00000012")
        state3, rep3 = loop.run(step_fn, state0, frozen, data, tcfg,
                                ckpt_dir=str(tmp_path), ckpt_every=5,
                                log_every=0, log_fn=lambda s: None)
        assert rep3.resumed_from == 10
        assert rep3.steps_run == 2

    def test_data_determinism_across_restarts(self):
        data = SyntheticLM(vocab=32, batch=4, seq=8, seed=11)
        b1 = data.batch_at(7)
        data2 = SyntheticLM(vocab=32, batch=4, seq=8, seed=11)
        b2 = data2.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        s0 = data.batch_at(3, shard=0, num_shards=2)
        s1 = data.batch_at(3, shard=1, num_shards=2)
        full = data.batch_at(3)
        np.testing.assert_array_equal(
            np.concatenate([s0["tokens"], s1["tokens"]]), full["tokens"])


ELASTIC_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_mesh   # version-guarded axis_types
mesh = make_mesh((%(ndev)d,), ("model",))
w = jnp.arange(64.0).reshape(8, 8)
sharded = jax.device_put(w, NamedSharding(mesh, P(None, "model")))
if "%(mode)s" == "save":
    ckpt.save_sync(sys.argv[1], 3, {"w": sharded})
else:
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    out, step = ckpt.restore(sys.argv[1], shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8,8))
    assert len(out["w"].sharding.device_set) == %(ndev)d
print("OK")
"""


@pytest.mark.parametrize("save_dev,load_dev", [(4, 2), (2, 8)])
def test_elastic_reshard_across_device_counts(tmp_path, save_dev, load_dev):
    """Save sharded on N devices, restore sharded on M != N (elastic)."""
    env = dict(os.environ, PYTHONPATH="src")
    for mode, ndev in (("save", save_dev), ("load", load_dev)):
        script = ELASTIC_SCRIPT % {"ndev": ndev, "mode": mode}
        r = subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                           capture_output=True, text=True, env=env,
                           cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
