"""Distribution tests: sharding rules, HLO analyzer (validated against
known-truth programs), gradient compression (error-feedback property),
MoE routing invariants, and a small-mesh dry-run integration test run in a
subprocess with 8 fake devices."""
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist import hlo
from repro.models import moe as moe_mod
from repro.configs.base import MoEConfig


class TestHloAnalyzer:
    def _stats(self, fn, *args):
        txt = jax.jit(fn).lower(*args).compile().as_text()
        return hlo.analyze_module(txt)

    def test_plain_matmul_exact(self):
        A = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
        s = self._stats(lambda a: a @ a, A)
        assert abs(s.dot_flops - 2 * 128 ** 3) / (2 * 128 ** 3) < 0.01

    def test_scan_trip_count_scaling(self):
        A = jax.random.normal(jax.random.PRNGKey(0), (128, 128))

        def f(a):
            out, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ a), None), a,
                                  None, length=8)
            return out
        s = self._stats(f, A)
        truth = 8 * 2 * 128 ** 3
        assert abs(s.dot_flops - truth) / truth < 0.02

    def test_grad_remat_scan_scaling(self):
        A = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
        x0 = jax.random.normal(jax.random.PRNGKey(1), (128, 128))

        def f(a, x0):
            def loss(w):
                def body(c, _):
                    return jnp.tanh(c @ w), None
                out, _ = jax.lax.scan(jax.checkpoint(body), x0, None, length=6)
                return out.sum()
            return jax.grad(loss)(a)
        s = self._stats(f, A, x0)
        truth = (6 + 6 + 12) * 2 * 128 ** 3   # fwd + recompute + bwd
        assert abs(s.dot_flops - truth) / truth < 0.05

    def test_shape_bytes_parsing(self):
        assert hlo._shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
        assert hlo._shape_bytes("bf16[2,4]") == 16
        assert hlo._shape_bytes("(f32[4], s32[2,2])") == 16 + 16
        assert hlo._shape_bytes("pred[]") == 1

    def test_collective_detection(self):
        txt = '''ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64] parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p), replica_groups={}, to_apply=%add
}
'''
        s = hlo.analyze_module(txt)
        assert s.collective_bytes == 256
        assert s.count_by_kind.get("all-reduce") == 1


class TestCompression:
    def test_quantize_roundtrip_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        from repro.dist.compression import dequantize, quantize_int8
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 100))
    def test_error_feedback_converges(self, seed):
        """Accumulated EF-compressed sums converge to the true sum: the
        residual stays bounded while the signal accumulates."""
        from repro.dist.compression import quantize_int8
        rng = np.random.default_rng(seed)
        x = rng.normal(size=64).astype(np.float32)
        err = np.zeros_like(x)
        acc = np.zeros_like(x)
        for t in range(64):
            y = x + err
            scale = max(np.abs(y).max(), 1e-12) / 127.0
            q = np.clip(np.round(y / scale), -127, 127)
            sent = q * scale
            err = y - sent
            acc += sent
        # mean of sent == x up to residual/T
        np.testing.assert_allclose(acc / 64, x, atol=np.abs(x).max() / 50 + 1e-3)


class TestMoERouting:
    def test_topk_and_renormalization(self):
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16)
        logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
        gates, ids, aux = moe_mod.route(logits, cfg)
        assert gates.shape == (32, 2) and ids.shape == (32, 2)
        np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-5)
        assert float(aux) > 0.5  # E * sum f*p >= 1 at uniform

    def test_capacity_and_slots(self):
        ids = jnp.array([[0], [0], [0], [1]])
        slots, keep = moe_mod.assign_slots(ids, num_experts=2, cap=2)
        assert keep.tolist() == [[True], [True], [False], [True]]
        assert slots[0, 0] == 0 and slots[1, 0] == 1

    def test_moe_ffn_identity_when_experts_equal(self):
        """If all experts share weights, routing must not matter."""
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                        capacity_factor=4.0)
        d = 16
        key = jax.random.PRNGKey(0)
        wi = jax.random.normal(key, (1, d, 8)) * 0.3
        wg = jax.random.normal(jax.random.fold_in(key, 1), (1, d, 8)) * 0.3
        wo = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, d)) * 0.3
        p = {
            "router": jax.random.normal(jax.random.fold_in(key, 3), (d, 4)),
            "we_i": jnp.tile(wi, (4, 1, 1)),
            "we_g": jnp.tile(wg, (4, 1, 1)),
            "we_o": jnp.tile(wo, (4, 1, 1)),
        }
        x = jax.random.normal(jax.random.fold_in(key, 4), (2, 8, d))
        y, _ = moe_mod.moe_ffn(x, p, cfg)
        # reference: plain gated mlp with the shared expert weights
        h = jnp.einsum("bsd,df->bsf", x, wi[0])
        g = jnp.einsum("bsd,df->bsf", x, wg[0])
        ref = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, wo[0])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro.configs as C
from repro.launch import dryrun_lib as dl
from repro.launch.mesh import make_mesh
from repro.configs.base import ShapeConfig

orig_get = C.get
dl.configs.get = lambda a: C.reduced(orig_get(a), layers=2, width=64, vocab=256)
shapes = {"train_4k": ShapeConfig("train_4k", 128, 8, "train"),
          "decode_32k": ShapeConfig("decode_32k", 256, 8, "decode")}
dl.configs.shape_for = lambda n: shapes[n]
mesh = make_mesh((4, 2), ("data", "model"))
for arch in ["yi-6b", "olmoe-1b-7b", "zamba2-7b"]:
    for shape in ["train_4k", "decode_32k"]:
        cell = dl.build_cell(arch, shape, mesh)
        with mesh:
            compiled = dl.lower_cell(cell).compile()
        res = dl.analyze(cell, None, compiled, mesh, 0.0)
        assert res["flops_per_device"] > 0
        assert res["memory"]["fits_hbm"]
print("MINI_DRYRUN_OK")
"""


def test_mini_dryrun_integration(tmp_path):
    """End-to-end: sharded lower + compile + roofline analysis on a 4x2 mesh
    for three families (dense, MoE, hybrid) x (train, decode)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MINI_DRYRUN_OK" in r.stdout
