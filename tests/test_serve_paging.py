"""Paged KV cache with shared-prefix reuse (DESIGN.md §Paging): allocator
refcount/leak invariants (property + fuzz), block-table manager lifecycle,
prefix sharing + COW byte-preservation, fp32 bit-exactness of the paged
runtime vs the dense-cache runtime and the serial engine (staggered
arrivals, heterogeneous adapters), zero decode recompiles across churn,
page-exhaustion deferral, and the capacity-bound boundary (generate at
exactly max_len) on all three serving paths."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.checkpoint import adapters as adapter_ckpt
from repro.configs.base import PEFTConfig
from repro.core import adapter as adapter_api
from repro.core import peft as peft_mod
from repro.models import build
from repro.serve import (
    ContinuousScheduler, Drafter, Engine, OutOfPagesError, PageAllocator,
    PagedKVCache, PageError, Request, SelfDrafter,
)
from repro.serve.engine import AdapterBank


def _cfg(arch="yi-6b"):
    return C.reduced(C.get(arch)).replace(vocab=64, param_dtype="float32",
                                          dtype="float32")


def _base_model():
    model = build(_cfg(), PEFTConfig(method="none"))
    return model, model.init(jax.random.PRNGKey(0))


def _serial(engine, req):
    if req.adapter_id is not None and \
            req.adapter_id not in engine.bank.resident_ids:
        engine.bank.load_from_checkpoint(req.adapter_id)
    out = engine.generate([req.prompt], max_new=req.max_new,
                          adapter_ids=[req.adapter_id]
                          if engine.bank is not None else None)[0]
    return [int(t) for t in np.asarray(out).reshape(-1)]


# ---------------------------------------------------------------------------
# PageAllocator invariants
# ---------------------------------------------------------------------------

class TestPageAllocator:
    def _fuzz(self, ops):
        """Drive alloc/ref/free against an external refcount model: counts
        never go negative (misuse raises PageError), nothing leaks."""
        alloc = PageAllocator(12, n_reserved=2)
        refs = {}                              # page -> expected refcount
        for op, arg in ops:
            if op == "alloc":
                if len(refs) == 10:
                    with pytest.raises(OutOfPagesError):
                        alloc.alloc()
                else:
                    p = alloc.alloc()
                    assert p >= 2 and p not in refs
                    refs[p] = 1
            elif op == "ref":
                p = 2 + arg % 10
                if p in refs:
                    alloc.ref(p)
                    refs[p] += 1
                else:
                    with pytest.raises(PageError):
                        alloc.ref(p)
            else:                              # free
                p = 2 + arg % 10
                if p in refs:
                    alloc.free(p)
                    refs[p] -= 1
                    if refs[p] == 0:
                        del refs[p]
                else:
                    with pytest.raises(PageError):
                        alloc.free(p)
            for p in range(2, 12):
                assert alloc.refcount(p) == refs.get(p, 0)
                assert alloc.refcount(p) >= 0
            assert alloc.free_count() == 10 - len(refs)

    @given(st.lists(st.tuples(st.sampled_from(["alloc", "ref", "free"]),
                              st.integers(min_value=0, max_value=9)),
                    max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_refcount_invariants_property(self, ops):
        self._fuzz(ops)

    def test_refcount_invariants_fuzz(self):
        rng = random.Random(0)
        for _ in range(20):
            ops = [(rng.choice(["alloc", "ref", "free"]), rng.randrange(10))
                   for _ in range(200)]
            self._fuzz(ops)

    def test_reserved_pages_untouchable(self):
        alloc = PageAllocator(4, n_reserved=2)
        with pytest.raises(PageError):
            alloc.free(0)
        with pytest.raises(PageError):
            alloc.ref(1)
        assert sorted(alloc.alloc() for _ in range(2)) == [2, 3]


# ---------------------------------------------------------------------------
# PagedKVCache manager lifecycle
# ---------------------------------------------------------------------------

class TestPagedKVCacheManager:
    def test_admit_release_cycles_no_leak(self):
        """N random admit/release cycles: refcounts always equal holder
        counts, every page returns to the free list once prefix entries
        are evicted."""
        rng = random.Random(1)
        pager = PagedKVCache(n_slots=3, max_len=32, page_size=4)
        live = {}
        for step in range(300):
            if live and (len(live) == 3 or rng.random() < 0.5):
                slot = rng.choice(list(live))
                del live[slot]
                pager.release(slot)
            else:
                slot = next(s for s in range(3) if s not in live)
                S = rng.randrange(1, 20)
                prompt = np.asarray([rng.randrange(64) for _ in range(S)])
                mn = rng.randrange(1, 32 - S + 2)
                plan = pager.plan_admit(slot, prompt, mn)
                if plan is not None:
                    pager.register_prompt(plan)
                    live[slot] = True
                    assert plan.prefix_len + len(plan.tail) == S
            pager.assert_no_leaks()
        for slot in list(live):
            pager.release(slot)
        pager.assert_no_leaks()
        pager.prefix_cache.evict_until_free(pager.n_pages)
        assert pager.allocator.free_count() == pager.n_pages - pager.n_slots

    def test_shared_prefix_maps_same_pages(self):
        pager = PagedKVCache(n_slots=2, max_len=32, page_size=4)
        prompt = np.arange(11)
        a = pager.plan_admit(0, prompt, 4)
        pager.register_prompt(a)
        b = pager.plan_admit(1, np.concatenate([prompt[:8], [50, 51]]), 4)
        pager.register_prompt(b)
        assert a.prefix_len == 0                       # cold: full prefill
        assert b.prefix_len == 8                       # two full chunks hit
        assert list(b.block_row[:2]) == list(a.block_row[:2])
        assert b.block_row[2] != a.block_row[2]        # divergent page: own
        for p in a.block_row[:2]:
            assert pager.allocator.refcount(int(p)) == 3   # 2 slots + cache
        pager.release(0)
        pager.release(1)
        for p in a.block_row[:2]:
            assert pager.allocator.refcount(int(p)) == 1   # cache retains
        pager.assert_no_leaks()

    def test_adapter_id_keys_the_prefix(self):
        """Factored adapters make prefix KV tenant-dependent: the chain
        hash is seeded with the adapter id, so cross-tenant prompts never
        share pages even when the tokens match."""
        pager = PagedKVCache(n_slots=2, max_len=32, page_size=4)
        prompt = np.arange(8)
        a = pager.plan_admit(0, prompt, 4, adapter_id="tenant-a")
        pager.register_prompt(a)
        b = pager.plan_admit(1, prompt, 4, adapter_id="tenant-b")
        pager.register_prompt(b)
        assert b.prefix_len == 0
        assert set(a.block_row[:2]).isdisjoint(set(b.block_row[:2]))
        pager.release(0)
        pager.release(1)
        c = pager.plan_admit(0, prompt, 4, adapter_id="tenant-a")
        assert c.prefix_len == 7                       # same tenant: COW hit
        pager.release(0)
        pager.assert_no_leaks()

    def test_cow_plan_on_exact_prefix_prompt(self):
        """A prompt that IS a cached page-aligned prefix recomputes only
        its last token, into a CLONE of the final shared page."""
        pager = PagedKVCache(n_slots=2, max_len=32, page_size=4)
        prompt = np.arange(8)
        a = pager.plan_admit(0, prompt, 4)
        pager.register_prompt(a)
        b = pager.plan_admit(1, prompt, 4)
        assert b.cow is not None and b.prefix_len == 7
        src, dst = b.cow
        assert src == a.block_row[1] and dst == b.block_row[1]
        assert b.block_row[0] == a.block_row[0]        # page 0 truly shared
        assert len(b.tail) == 1 and b.tail[0] == prompt[-1]
        pager.release(0)
        pager.release(1)
        pager.assert_no_leaks()

    def test_eviction_cannot_free_just_matched_pages(self):
        """Regression: under pool pressure, plan_admit's LRU eviction must
        not free the shared pages it just matched (refcount-1 cache-only
        entries are exactly what eviction targets) — matching pins first.
        Unfixed this raised PageError('ref of unallocated page') and
        crashed the serving loop instead of deferring/admitting."""
        pager = PagedKVCache(n_slots=1, max_len=32, page_size=4, n_pages=9)
        prompt = np.arange(8)
        pager.register_prompt(pager.plan_admit(0, prompt, 23))  # 2 chunks
        pager.release(0)
        # needs 7 owned pages with only 6 free: forces eviction while the
        # matched chunks are the only evictable entries — the pins make
        # eviction skip them, and the cold fallback then reclaims the match
        # to admit anyway (unfixed: PageError crash out of allocator.ref)
        plan = pager.plan_admit(0, prompt, 25)
        assert plan is not None and plan.prefix_len == 0   # cold fallback
        pager.release(0)
        pager.assert_no_leaks()

    def test_cow_at_capacity_bound_on_minimal_pool_falls_back_cold(self):
        """Regression: a fully-cached page-aligned prompt at the capacity
        bound needs pps+1 pages on the COW path (pinned src + clone), which
        a minimal pool (n_slots + pps) can never supply — plan_admit must
        give the match back and run a cold prime rather than defer forever
        (which hard-crashed events() with 'scheduler stalled')."""
        pager = PagedKVCache(n_slots=1, max_len=32, page_size=8, n_pages=5)
        prompt = np.arange(32)
        plan = pager.plan_admit(0, prompt, 1)
        assert plan is not None and plan.prefix_len == 0
        pager.register_prompt(plan)
        pager.release(0)
        plan = pager.plan_admit(0, prompt, 1)      # full COW match, 0 free
        assert plan is not None and plan.prefix_len == 0   # cold fallback
        pager.release(0)
        pager.assert_no_leaks()
        # end-to-end: the scheduler serves it instead of stalling
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=1, max_len=32)
        sched = ContinuousScheduler(eng, page_size=8, n_pages=5)
        for _ in range(2):
            reqs = [Request(prompt=jnp.asarray(prompt, jnp.int32),
                            max_new=1)]
            sched.serve(reqs)
            assert reqs[0].out == _serial(eng, reqs[0])
        sched.pager.assert_no_leaks()

    def test_plan_rejects_oversized_and_defers_on_pressure(self):
        pager = PagedKVCache(n_slots=2, max_len=16, page_size=4,
                             n_pages=2 + 4)            # ONE full window
        with pytest.raises(ValueError, match="pages_per_seq"):
            pager.plan_admit(0, np.arange(10), 16)
        plan = pager.plan_admit(0, np.arange(10), 7)   # all 4 pages
        assert plan is not None
        pager.register_prompt(plan)
        assert pager.plan_admit(1, np.arange(5), 4) is None   # defer
        pager.release(0)
        pager.prefix_cache.evict_until_free(pager.n_pages)
        assert pager.plan_admit(1, np.arange(5), 4) is not None
        pager.release(1)
        pager.assert_no_leaks()


class TestEvictExactlyEnough:
    """DESIGN.md §Tiering: `evict_until_free(need)` frees exactly what
    refcounts allow — never overshooting past `need` — and reports the
    shortfall instead of silently stopping short."""

    def _pool(self):
        """Chain A (3 chunk pages, released -> evictable leaf-first) and
        chain B (2 chunk pages, pinned by live slot 1)."""
        pager = PagedKVCache(n_slots=2, max_len=32, page_size=4, n_pages=16)
        a = pager.plan_admit(0, np.arange(13), 4)       # 3 full chunks
        pager.register_prompt(a)
        pager.release(0)
        b = pager.plan_admit(1, np.arange(40, 49), 4)   # 2 full chunks
        pager.register_prompt(b)
        return pager

    @given(st.integers(0, 16))
    @settings(max_examples=25, deadline=None)
    def test_exactly_enough_and_shortfall_property(self, need):
        pager = self._pool()
        before = pager.allocator.free_count()
        evicted, shortfall = pager.prefix_cache.evict_until_free(need)
        after = pager.allocator.free_count()
        assert evicted == after - before
        assert after <= max(before, need)      # never frees past `need`
        assert shortfall == max(0, need - after)
        # the pinned chain is untouchable: slot 1's prompt still fully
        # matches once released, whatever `need` demanded
        pager.release(1)
        plan = pager.plan_admit(1, np.arange(40, 49), 4)
        assert plan.prefix_len == 8
        pager.release(1)
        pager.assert_no_leaks()

    def test_leaf_first_keeps_chain_prefix_matchable(self):
        pager = self._pool()
        before = pager.allocator.free_count()
        evicted, shortfall = pager.prefix_cache.evict_until_free(before + 1)
        assert (evicted, shortfall) == (1, 0)
        # the evicted page was chain A's LEAF: the surviving prefix still
        # matches (an interior eviction would orphan the whole chain)
        plan = pager.plan_admit(0, np.arange(13), 4)
        assert plan.prefix_len == 8
        pager.release(0)
        pager.release(1)

    def test_reinserted_ancestor_relinks_cached_children(self):
        """Regression: a child inserted while its ancestor is absent must
        still count toward the ancestor when that key is (re-)inserted —
        otherwise leaf-first eviction could drop the interior chunk while
        the descendant stays cached, stranding it (match stops at the
        first miss) with its page still allocated."""
        from repro.serve.paging import PrefixCache

        alloc = PageAllocator(6, n_reserved=1)
        cache = PrefixCache(alloc)
        pa, pb = alloc.alloc(), alloc.alloc()
        cache.insert(b"B", pb, parent=b"A")     # ancestor A not cached yet
        cache.insert(b"A", pa, parent=None)     # ...now (re-)inserted
        alloc.free(pa)
        alloc.free(pb)                          # cache is the only holder
        assert cache.match([b"A", b"B"]) == [pa, pb]   # chain healed; this
        evicted, shortfall = cache.evict_until_free(   # also makes A LRU-
            alloc.free_count() + 1)                    # older than B
        assert (evicted, shortfall) == (1, 0)
        # the LEAF (B) went, not the LRU-older interior chunk (A): the
        # chain head must still be matchable
        assert cache.match([b"A", b"B"]) == [pa]

    def test_shortfall_reported_when_everything_is_pinned(self):
        pager = PagedKVCache(n_slots=1, max_len=32, page_size=4, n_pages=9)
        plan = pager.plan_admit(0, np.arange(13), 4)
        pager.register_prompt(plan)                    # slot 0 stays live
        before = pager.allocator.free_count()
        evicted, shortfall = pager.prefix_cache.evict_until_free(before + 3)
        assert evicted == 0                            # all pinned by slot 0
        assert shortfall == 3
        assert pager.allocator.free_count() == before
        pager.release(0)
        pager.assert_no_leaks()


# ---------------------------------------------------------------------------
# End-to-end exactness: paged runtime vs dense runtime vs serial engine
# ---------------------------------------------------------------------------

PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], [11, 12], [3, 1, 4, 1, 5, 9],
           [2, 7, 1, 8], [6, 6, 6], [9, 8, 7, 6, 5, 4, 3], [5, 5]]


def _trace(max_news, adapter_ids=None):
    return [Request(prompt=jnp.array(PROMPTS[i % len(PROMPTS)], jnp.int32),
                    max_new=mn,
                    adapter_id=adapter_ids[i] if adapter_ids else None)
            for i, mn in enumerate(max_news)]


class TestPagedExactness:
    def test_paged_bitwise_equals_dense_and_serial(self):
        """Acceptance: the paged runtime reproduces the dense-cache runtime
        AND the serial engine bit-for-bit (fp32) on the staggered trace."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        arrivals = [0, 0, 1, 2, 3, 5, 8, 9]
        budgets = [4, 7, 2, 5, 1, 6, 3, 8]
        paged = _trace(budgets)
        ContinuousScheduler(eng, page_size=8).serve(paged, arrivals)
        dense = _trace(budgets)
        ContinuousScheduler(eng, paged=False).serve(dense, arrivals)
        assert [r.out for r in paged] == [r.out for r in dense]
        for r in paged:
            assert r.out == _serial(eng, r)

    def test_heterogeneous_adapters_paged_bitwise(self, tmp_path):
        """Mixed tenants (two methods + bare base) through the PAGED
        runtime reproduce each request's serial outputs exactly."""
        model, params = _base_model()
        profiles = {
            "fourierft": PEFTConfig(method="fourierft", n=16, alpha=25.0,
                                    param_dtype="float32"),
            "lora": PEFTConfig(method="lora", lora_r=2,
                               param_dtype="float32"),
        }
        for i, (tid, m) in enumerate(zip(("tenant-fft", "tenant-lora"),
                                         ("fourierft", "lora"))):
            prof = profiles[m]
            tree = peft_mod.init_adapters(jax.random.PRNGKey(10 + i),
                                          model.sites, prof)
            tree = jax.tree.map(
                lambda x: x + 0.05 if jnp.issubdtype(x.dtype, jnp.floating)
                else x, tree)
            trainable = set(adapter_api.resolve(m).trainable_leaves(prof))
            tree = {s: {k: v for k, v in d.items() if k in trainable}
                    for s, d in tree.items()}
            adapter_ckpt.export_adapter(str(tmp_path), tid, tree, prof)
        bank = AdapterBank(model, profiles, capacity=4,
                           checkpoint_dir=str(tmp_path))
        eng = Engine(model, params, batch_slots=3, max_len=48, bank=bank)
        ids = ["tenant-fft", "tenant-lora", None, "tenant-fft",
               "tenant-lora", None]
        reqs = _trace([5, 3, 6, 2, 4, 3], adapter_ids=ids)
        ContinuousScheduler(eng, page_size=8).serve(
            reqs, arrivals=[0, 0, 0, 1, 3, 4])
        for r in reqs:
            assert r.out == _serial(eng, r)

    def test_shared_prefix_traffic_exact_and_cow_preserves_bytes(self):
        """Requests sharing a page-aligned system prompt reuse its pages —
        including the full-prompt COW case — and stay bit-exact; the shared
        pages' bytes survive every borrower untouched."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        sys_p = list((np.arange(16) * 3 + 1) % 64)
        cold = Request(prompt=jnp.array(sys_p + [2, 9], jnp.int32),
                       max_new=4)
        sched.serve([cold])
        assert len(sched.pager.prefix_cache) == 2
        shared_pages = list(sched.pager.prefix_cache.pages)
        before = np.asarray(sched.cache["pk"][:, shared_pages])
        tails = [[7], [13, 21, 3], []]       # [] => prompt == prefix: COW
        reqs = [Request(prompt=jnp.array(sys_p + t, jnp.int32), max_new=4)
                for t in tails]
        sched.serve(reqs, arrivals=[0, 1, 2])
        after = np.asarray(sched.cache["pk"][:, shared_pages])
        np.testing.assert_array_equal(before, after)
        for r in [cold] + reqs:
            assert r.out == _serial(eng, r)
        sched.pager.assert_no_leaks()

    def test_zero_decode_recompiles_across_churn(self):
        """Acceptance: after the first admissions the paged decode graph
        never recompiles — churn only changes block-table VALUES. Asserted
        through the analyzer's recompile audit: actual jit signature
        counts vs the scheduler's own `expected_compile_bounds()`
        contract (decode = exactly 1 graph; prime prefills log-bounded by
        the pow2 buckets), instead of the old before/after cache-size
        probe that couldn't say WHAT was allowed to compile."""
        from repro.analysis import hlo_lint
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8)
        sched.serve(_trace([3, 1, 4, 2, 5]))
        reqs = _trace([2, 4, 1, 3, 2, 5, 1, 2])
        sched.serve(reqs, arrivals=[0, 0, 1, 2, 2, 3, 5, 6])
        assert hlo_lint.scheduler_recompile_findings(sched) == []
        assert sched.compiled_signatures()["decode"] == 1
        for r in reqs:
            assert r.out is not None
        sched.pager.assert_no_leaks()

    def test_page_exhaustion_defers_not_fails(self):
        """A request that cannot get its worst-case pages waits for a slot
        to drain (like a pinned-full bank) and then completes exactly."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=32)
        sched = ContinuousScheduler(eng, page_size=8,
                                    n_pages=2 + 4)     # ONE full window
        reqs = [Request(prompt=jnp.array(PROMPTS[0], jnp.int32), max_new=28),
                Request(prompt=jnp.array(PROMPTS[1], jnp.int32), max_new=6)]
        for r in reqs:
            sched.submit(r)
        events = list(sched.events())
        admit_t = {e[1]: e[3] for e in events if e[0] == "admit"}
        done_t = {e[1]: e[3] for e in events if e[0] == "done"}
        assert admit_t[1] >= done_t[0]       # waited for pages, not a slot
        for r in reqs:
            assert r.out == _serial(eng, r)
        sched.pager.assert_no_leaks()


class _ChaosDrafter(Drafter):
    """Adversarial drafter: proposes seeded random garbage, so verify
    rejects almost every draft — maximal rollback traffic, every window's
    tail rows written then abandoned past kv_len. Correctness must not
    depend on proposal quality."""

    def __init__(self, k, seed):
        self.k = k
        self._rng = np.random.default_rng(seed)

    def propose(self):
        s = self._sched
        return self._rng.integers(0, 64, size=(s.n_slots, self.k),
                                  dtype=np.int32)


class TestSpecRollback:
    """DESIGN.md §Speculation rollback invariants on the PAGED cache:
    speculation is position bookkeeping only — no page ever allocates,
    frees, or mutates because of a rejected draft."""

    def test_rejected_windows_exact_and_leak_free(self):
        """Worst case (garbage drafter, ~everything rejected): outputs stay
        bit-identical to serial and the allocator ends leak-free."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8,
                                    drafter=_ChaosDrafter(k=3, seed=0))
        reqs = _trace([4, 7, 2, 5, 1, 6])
        sched.serve(reqs, arrivals=[0, 0, 1, 2, 3, 5])
        for r in reqs:
            assert r.out == _serial(eng, r)
        sched.pager.assert_no_leaks()

    @pytest.mark.parametrize("k", [1, 4])
    def test_fuzz_churn_under_speculation(self, k):
        """Fuzz: random budgets/arrivals through the speculative runtime —
        every request exact, allocator leak-free after every drain."""
        rng = random.Random(17 + k)
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8,
                                    drafter=_ChaosDrafter(k=k, seed=k))
        for _ in range(3):
            n = rng.randint(2, 5)
            reqs = _trace([rng.randint(1, 8) for _ in range(n)])
            arrivals = sorted(rng.randint(0, 4) for _ in range(n))
            sched.serve(reqs, arrivals=arrivals)
            sched.pager.assert_no_leaks()
            for r in reqs:
                assert r.out == _serial(eng, r)

    def test_shared_prefix_pages_survive_speculation(self):
        """Refcounted shared-prefix pages are READ-ONLY to the verify
        window: overflow rows route to the slot's reserved scratch page,
        never onto a shared page. The shared pages' bytes must survive
        speculative borrowers untouched (self-drafter probes included)."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=3, max_len=48)
        sched = ContinuousScheduler(eng, page_size=8,
                                    drafter=SelfDrafter(k=3))
        sys_p = list((np.arange(16) * 3 + 1) % 64)
        cold = Request(prompt=jnp.array(sys_p + [2, 9], jnp.int32),
                       max_new=4)
        sched.serve([cold])
        assert len(sched.pager.prefix_cache) == 2
        shared_pages = list(sched.pager.prefix_cache.pages)
        before = np.asarray(sched.cache["pk"][:, shared_pages])
        tails = [[7], [13, 21, 3], []]       # [] => prompt == prefix: COW
        reqs = [Request(prompt=jnp.array(sys_p + t, jnp.int32), max_new=6)
                for t in tails]
        sched.serve(reqs, arrivals=[0, 1, 2])
        after = np.asarray(sched.cache["pk"][:, shared_pages])
        np.testing.assert_array_equal(before, after)
        for r in [cold] + reqs:
            assert r.out == _serial(eng, r)
        sched.pager.assert_no_leaks()

    def test_speculation_never_touches_the_allocator(self):
        """Property: the page-allocator op sequence is IDENTICAL with and
        without a drafter — speculation introduces zero alloc/free calls."""
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=48)

        def trace_ops(drafter):
            sched = ContinuousScheduler(eng, page_size=8, drafter=drafter)
            ops = []
            alloc = sched.pager.allocator
            real_alloc, real_free = alloc.alloc, alloc.free
            alloc.alloc = lambda *a, **k: (ops.append("alloc"),
                                           real_alloc(*a, **k))[1]
            alloc.free = lambda *a, **k: (ops.append("free"),
                                          real_free(*a, **k))[1]
            sched.serve(_trace([5, 3, 6, 2]), arrivals=[0, 0, 2, 3])
            return ops

        assert trace_ops(None) == trace_ops(_ChaosDrafter(k=3, seed=5))


class TestCapacityBoundary:
    """Satellite: the `prompt + max_new - 1 <= max_len` bound, proven by
    generating at exactly max_len on every serving path."""

    def test_scheduler_generates_at_exactly_max_len(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=16)
        for paged in (True, False):
            prompt = jnp.array(PROMPTS[0], jnp.int32)          # S=5
            reqs = [Request(prompt=prompt, max_new=12)]        # 5+12-1 == 16
            ContinuousScheduler(eng, paged=paged,
                                page_size=4).serve(reqs)
            assert len(reqs[0].out) == 12
            assert reqs[0].out == _serial(eng, reqs[0])

    def test_generate_boundary(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=16)
        p = jnp.array(PROMPTS[0], jnp.int32)
        out = eng.generate([p], max_new=12)[0]                 # exactly 16
        assert out.shape[0] == 12
        with pytest.raises(ValueError, match="max_len"):
            eng.generate([p], max_new=13)

    def test_generate_requests_boundary(self):
        model, params = _base_model()
        eng = Engine(model, params, batch_slots=2, max_len=16)
        p = jnp.array(PROMPTS[0], jnp.int32)
        reqs = [Request(prompt=p, max_new=12)]
        eng.generate_requests(reqs)
        assert len(reqs[0].out) == 12
        assert reqs[0].out == _serial(eng, reqs[0])
        with pytest.raises(ValueError, match="max_len"):
            eng.generate_requests([Request(prompt=p, max_new=13)])
