"""Per-arch smoke tests (reduced configs, one forward + train step, shape and
finiteness assertions) plus model-level correctness: SSD oracle, decode ==
prefill, chunked == direct attention, GQA/M-RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import PEFTConfig, TrainConfig
from repro.models import build, mamba2
from repro.models.attention import chunked_attention, direct_attention
from repro.models.common import apply_rope
from repro.train import step as ts


def _batch_for(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.bfloat16) * 0.02,
            "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                          (3, B, S)),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    if cfg.n_codebooks:
        t = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab)
        return {"tokens": t, "labels": t}
    t = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": t, "labels": t}


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config of the same family, one
    forward/train step on CPU, output shapes + no NaNs."""
    cfg = C.reduced(C.get(arch))
    model = build(cfg, PEFTConfig(n=16, alpha=10.0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    params = model.init(jax.random.PRNGKey(0))
    logits, aux = model.forward(params, batch)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one jitted train step
    tcfg = TrainConfig(total_steps=1, warmup_steps=1)
    state, frozen = ts.init_state(model, tcfg, jax.random.PRNGKey(1))
    step_fn = jax.jit(ts.make_train_step(model, tcfg))
    state, metrics = step_fn(state, frozen, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    assert int(metrics["skipped"]) == 0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = C.reduced(C.get(arch))
    model = build(cfg, PEFTConfig(n=16, alpha=10.0))
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 16)
    b = _batch_for(cfg, B, 1)
    b.pop("labels")
    toks, cache2 = model.decode_step(params, cache, b)
    if cfg.n_codebooks:
        assert toks.shape == (B, cfg.n_codebooks)
    else:
        assert toks.shape == (B,)
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-4b", "qwen2.5-32b",
                                  "olmoe-1b-7b", "mamba2-2.7b", "zamba2-7b",
                                  "musicgen-medium"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the full-forward argmax exactly
    (validates KV caches, SSM state carry, shared-block caches, rope offsets)."""
    cfg = C.reduced(C.get(arch)).replace(param_dtype="float32",
                                         dtype="float32")
    model = build(cfg, PEFTConfig(n=16, alpha=10.0, param_dtype="float32"))
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, seed=2)
    batch.pop("labels")
    logits, _ = model.forward(params, batch)
    full = jnp.argmax(logits, axis=-1)
    cache = model.init_cache(B, S + 2, dtype=jnp.float32)
    outs = []
    for t in range(S):
        if cfg.n_codebooks:
            bt = {"tokens": batch["tokens"][:, t:t + 1]}
        else:
            bt = {"tokens": batch["tokens"][:, t:t + 1]}
        nt, cache = model.decode_step(params, cache, bt)
        outs.append(nt)
    dec = jnp.stack(outs, axis=1)
    assert (dec == full).mean() == 1.0


class TestSSD:
    def test_chunked_matches_recurrence(self):
        key = jax.random.PRNGKey(0)
        b, S, H, P, G, N = 2, 64, 4, 8, 2, 16
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        B = jax.random.normal(ks[3], (b, S, G, N))
        Cm = jax.random.normal(ks[4], (b, S, G, N))
        D = jnp.ones((H,))
        y1, f1 = mamba2.ssd_chunked(x, dt, A, B, Cm, D, chunk=16)
        y2, f2 = mamba2.ssd_recurrent_oracle(x, dt, A, B, Cm, D)
        np.testing.assert_allclose(y1, y2, atol=1e-4)
        np.testing.assert_allclose(f1, f2, atol=1e-4)

    def test_chunk_size_invariance(self):
        key = jax.random.PRNGKey(1)
        b, S, H, P, G, N = 1, 64, 2, 4, 1, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))) * 0.1
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        B = jax.random.normal(ks[3], (b, S, G, N))
        Cm = jax.random.normal(ks[4], (b, S, G, N))
        D = jnp.zeros((H,))
        outs = [mamba2.ssd_chunked(x, dt, A, B, Cm, D, chunk=c)[0]
                for c in (8, 16, 32, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, atol=1e-4)


class TestAttention:
    def test_chunked_matches_direct(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, S, H, K, dh = 2, 1024, 8, 2, 32
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, K, dh))
        v = jax.random.normal(ks[2], (B, S, K, dh))
        o1 = chunked_attention(q, k, v, chunk_q=128)
        o2 = direct_attention(q, k, v)
        np.testing.assert_allclose(o1, o2, atol=2e-5)

    def test_causality(self):
        """Perturbing future tokens must not change earlier outputs."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        B, S, H, dh = 1, 256, 2, 16
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, H, dh))
        v = jax.random.normal(ks[2], (B, S, H, dh))
        o1 = chunked_attention(q, k, v, chunk_q=64)
        k2 = k.at[:, 200:].set(7.0)
        v2 = v.at[:, 200:].set(-3.0)
        o2 = chunked_attention(q, k2, v2, chunk_q=64)
        np.testing.assert_allclose(o1[:, :200], o2[:, :200], atol=1e-5)

    def test_gqa_equals_expanded_mha(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        B, S, H, K, dh = 2, 64, 8, 2, 16
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, K, dh))
        v = jax.random.normal(ks[2], (B, S, K, dh))
        o_gqa = direct_attention(q, k, v)
        o_mha = direct_attention(q, jnp.repeat(k, H // K, 2),
                                 jnp.repeat(v, H // K, 2))
        np.testing.assert_allclose(o_gqa, o_mha, atol=1e-5)

    def test_kv_len_masking(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 1, 4, 16))
        k = jax.random.normal(ks[1], (1, 32, 4, 16))
        v = jax.random.normal(ks[2], (1, 32, 4, 16))
        o1 = direct_attention(q, k, v, causal=False, kv_len=jnp.int32(10))
        k2 = k.at[:, 10:].set(5.0)
        o2 = direct_attention(q, k2, v, causal=False, kv_len=jnp.int32(10))
        np.testing.assert_allclose(o1, o2, atol=1e-6)


class TestRope:
    def test_relative_phase(self):
        """RoPE: <q_i, k_j> depends only on i - j."""
        dh = 32
        q = jnp.ones((1, 1, 1, dh))
        k = jnp.ones((1, 1, 1, dh))
        def score(i, j):
            qr = apply_rope(q, jnp.array([[i]]), 10000.0)
            kr = apply_rope(k, jnp.array([[j]]), 10000.0)
            return float(jnp.sum(qr * kr))
        assert abs(score(5, 3) - score(12, 10)) < 1e-4
        assert abs(score(5, 3) - score(7, 3)) > 1e-5

    def test_mrope_sections(self):
        from repro.models.common import mrope_sections
        assert mrope_sections(128) == (16, 24, 24)
        assert sum(mrope_sections(128)) == 64

    def test_mrope_matches_rope_when_streams_equal(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 1)[0]
        x = jax.random.normal(ks, (2, 8, 4, 128))
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
        pos3 = jnp.broadcast_to(pos, (3, 2, 8))
        a = apply_rope(x, pos, 10000.0, mrope=False)
        b = apply_rope(x, pos3, 10000.0, mrope=True)
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestZamba2Structure:
    def test_n_apps(self):
        cfg = C.get("zamba2-7b")
        from repro.models import zamba2
        assert cfg.num_layers == 81 and cfg.zamba.shared_every == 6
        assert zamba2.n_apps(cfg) == 14  # 13 full groups + tail
