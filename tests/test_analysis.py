"""Static analyzer tests (DESIGN.md §Analysis): per-rule AST fixtures
(tracer leak, host sync, in-loop sync, rng-in-jit, suppressions), kernel
capability verifier (exact derived int32 bounds, loosened-bound seeded
regression, conservative declarations pass, scratch mismatch), sharding
coverage (clean tree + uncovered-leaf seeded regression), jaxpr/HLO lint
(callback in a compiled loop, f32-literal upcast, donation miss, recompile
budgets), the baseline gate (new fails / baselined passes / stale reported),
and the CLI. The seeded regressions are the acceptance criteria: each pass
must fail the gate on its planted bug."""
import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ast_lint, hlo_lint, kernel_audit, report
from repro.analysis.report import Finding
from repro.kernels import api as kapi


def rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# AST lint
# ---------------------------------------------------------------------------

TRACER_IF = '''
import jax, jax.numpy as jnp

@jax.jit
def f(x):
    y = jnp.sum(x)
    if y:
        return y
    return y + 1
'''

TRACER_INT = '''
import jax, jax.numpy as jnp

@jax.jit
def f(x):
    return int(jnp.max(x))
'''

RNG_IN_JIT = '''
import jax

@jax.jit
def f(x):
    k = jax.random.PRNGKey(0)
    return jax.random.normal(k, x.shape) + x
'''

# the old SelfDrafter.propose shape (pre-PR serve/spec.py): device tokens
# stacked then pulled to host inside the proposal path
OLD_PROPOSE = '''
import jax.numpy as jnp
import numpy as np

def propose(sched):
    outs = []
    for _ in range(4):
        outs.append(sched.step())
    return np.asarray(jnp.stack(outs, axis=1))
'''

SYNC_IN_LOOP = '''
import jax.numpy as jnp
import numpy as np

def drain(xs):
    toks = []
    for x in xs:
        toks.append(np.asarray(jnp.argmax(x)))
    return toks
'''

SCAN_BODY_TRACED = '''
import jax, jax.numpy as jnp

def body(carry, x):
    if jnp.sum(x):
        carry = carry + 1
    return carry, x

def run(xs):
    return jax.lax.scan(body, 0, xs)
'''


class TestAstLint:
    def test_tracer_bool_on_if(self):
        assert rules(ast_lint.lint_source(TRACER_IF)) == ["tracer-bool"]

    def test_tracer_bool_on_int_coercion(self):
        assert rules(ast_lint.lint_source(TRACER_INT)) == ["tracer-bool"]

    def test_tracer_leak_fails_gate(self):
        """Seeded regression: a planted tracer leak fails the gate."""
        findings = ast_lint.lint_source(TRACER_IF)
        assert report.gate(findings, {}) == 1

    def test_rng_in_jit(self):
        assert rules(ast_lint.lint_source(RNG_IN_JIT)) == ["rng-in-jit"]

    def test_old_propose_regression_flagged(self):
        """The pre-PR SelfDrafter.propose host sync is a finding — and a
        planted host sync fails the gate."""
        findings = ast_lint.lint_source(OLD_PROPOSE)
        assert "host-sync" in rules(findings)
        assert report.gate(findings, {}) == 1

    def test_host_sync_in_loop(self):
        assert "host-sync-in-loop" in rules(ast_lint.lint_source(SYNC_IN_LOOP))

    def test_scan_body_is_traced_scope(self):
        """Functions passed to lax.scan are traced even without a jit
        decorator (the combinator pre-pass), regardless of def order."""
        assert "tracer-bool" in rules(ast_lint.lint_source(SCAN_BODY_TRACED))

    def test_suppression_on_line(self):
        src = OLD_PROPOSE.replace(
            "return np.asarray(jnp.stack(outs, axis=1))",
            "return np.asarray(jnp.stack(outs, axis=1))"
            "  # repro: allow(host-sync)")
        assert ast_lint.lint_source(src) == []

    def test_suppression_line_above(self):
        src = OLD_PROPOSE.replace(
            "    return np.asarray(jnp.stack(outs, axis=1))",
            "    # repro: allow(host-sync)\n"
            "    return np.asarray(jnp.stack(outs, axis=1))")
        assert ast_lint.lint_source(src) == []

    def test_suppression_is_rule_specific(self):
        src = OLD_PROPOSE.replace(
            "return np.asarray(jnp.stack(outs, axis=1))",
            "return np.asarray(jnp.stack(outs, axis=1))"
            "  # repro: allow(tracer-bool)")
        assert "host-sync" in rules(ast_lint.lint_source(src))

    def test_attribute_assign_does_not_poison_self(self):
        """`self.x = jnp.f(...)` must not mark `self` device-valued (the
        false positive that would flag every later self.* host read)."""
        src = '''
import jax.numpy as jnp
import numpy as np

class A:
    def set(self):
        self.x = jnp.zeros((4,))

    def get(self):
        return np.asarray(self.host_list)
'''
        assert ast_lint.lint_source(src) == []

    def test_reassignment_clears_device_name(self):
        src = '''
import jax.numpy as jnp
import numpy as np

def f(xs):
    y = jnp.sum(xs)
    y = [1, 2, 3]
    return np.asarray(y)
'''
        assert ast_lint.lint_source(src) == []


# ---------------------------------------------------------------------------
# Kernel capability verifier
# ---------------------------------------------------------------------------

class TestKernelAudit:
    def test_derived_bounds_exact(self):
        """First-principles int32 bounds: 46336 for the linear Fourier
        phase (block-padded 46336 rows x 46335 max index), 32768 for the
        half-integer DCT phase ((2*65535+1)... see kernel_audit)."""
        from repro.kernels import dct_deltaw, fourier_deltaw
        assert kernel_audit.derived_phase_bound(fourier_deltaw.CAPS) == 46336
        assert kernel_audit.derived_phase_bound(dct_deltaw.CAPS) == 32768

    def test_registry_clean(self):
        assert kernel_audit.run() == []

    def _op(self, method, backend="pallas", op="deltaw"):
        (found,) = [o for o in kapi.all_ops()
                    if (o.op, o.method, o.backend) == (op, method, backend)]
        return found

    def test_loosened_bound_fails_gate(self):
        """Seeded regression: declaring past the derived int32 bound is a
        finding and fails the gate."""
        bad = dataclasses.replace(self._op("fourierft"), max_dim=46400)
        findings = kernel_audit.audit_op(bad)
        assert rules(findings) == ["bound-loosened"]
        assert report.gate(findings, {}) == 1
        bad = dataclasses.replace(self._op("dct"), max_dim=33000)
        assert rules(kernel_audit.audit_op(bad)) == ["bound-loosened"]

    def test_conservative_bound_passes(self):
        """Declared BELOW derived is healthy (DCT ships 32500 < 32768);
        exactly AT derived also passes — only looser fails."""
        dct = self._op("dct")
        assert dct.max_dim == 32500
        assert kernel_audit.audit_op(dct) == []
        at = dataclasses.replace(dct, max_dim=32768)
        assert kernel_audit.audit_op(at) == []
        over = dataclasses.replace(dct, max_dim=32769)
        assert rules(kernel_audit.audit_op(over)) == ["bound-loosened"]

    def test_missing_max_dim_with_caps_flagged(self):
        bad = dataclasses.replace(self._op("fourierft"), max_dim=None)
        assert rules(kernel_audit.audit_op(bad)) == ["bound-missing"]

    def test_paged_scratch_mismatch(self):
        op = self._op("attention", op="paged_attention")
        assert op.caps is not None and kernel_audit.audit_op(op) == []
        caps = dict(op.caps)
        caps["scratch"] = {**caps["scratch"], "acc": ("K", "G", "W")}
        bad = dataclasses.replace(op, caps=caps)
        assert rules(kernel_audit.audit_op(bad)) == ["scratch-mismatch"]

    def test_capless_ops_skipped(self):
        assert kernel_audit.audit_op(
            self._op("fourierft", backend="einsum")) == []

    def test_constant_drift_detected(self, monkeypatch):
        from repro.kernels import ops
        monkeypatch.setattr(ops, "FOURIER_INT32_SAFE_DIM", 46500)
        assert rules(kernel_audit.declared_constants_findings()) \
            == ["constant-drift"]


# ---------------------------------------------------------------------------
# Sharding coverage
# ---------------------------------------------------------------------------

class TestShardingAudit:
    def test_tree_fully_covered(self):
        from repro.analysis import sharding_audit
        assert sharding_audit.run() == []

    def test_uncovered_leaf_flagged(self, monkeypatch):
        """Seeded regression: drop a mamba2 leaf from the replicate table
        and the audit names it (and the gate fails)."""
        from repro.analysis import sharding_audit
        from repro.dist import sharding
        monkeypatch.setattr(sharding, "_REPLICATE",
                            sharding._REPLICATE - {"A_log"})
        findings = sharding_audit.run(methods=("none",),
                                      archs=("mamba2-2.7b",))
        assert rules(findings) == ["uncovered"]
        assert "A_log" in findings[0].where
        assert report.gate(findings, {}) == 1

    def test_rule_kind_classification(self):
        from repro.dist.sharding import rule_kind
        assert rule_kind("base/wq", (2, 64, 64)) == "column"
        assert rule_kind("base/wo__b", (64,)) == "replicate"
        assert rule_kind("base/wi__b", (2, 128)) == "column"
        assert rule_kind("base/embed", (64, 64)) == "row"
        assert rule_kind("base/we_i", (2, 8, 64, 128)) == "expert"
        assert rule_kind("peft/attn.wq/c", (2, 16)) == "replicate"
        assert rule_kind("opt/count", ()) == "scalar"
        assert rule_kind("base/mystery_w", (64, 64)) is None


# ---------------------------------------------------------------------------
# jaxpr / HLO lint
# ---------------------------------------------------------------------------

HOT_LOOP_HLO = '''HloModule m

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4] get-tuple-element(%p), index=1
  %cb = f32[4] custom-call(%x), custom_call_target="xla_python_cpu_callback"
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]) tuple(%ni, %cb)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(32)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p0: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p0 = (s32[], f32[4]) parameter(0)
  ROOT %w = (s32[], f32[4]) while(%p0), condition=%cond, body=%body
}
'''


class TestHloLint:
    def test_hot_loop_host_transfer_weighted(self):
        """Seeded regression: a host callback in a while body is flagged
        at trip-count multiplicity and fails the gate."""
        findings = hlo_lint.lint_hlo_text(HOT_LOOP_HLO, "fix")
        assert rules(findings) == ["host-transfer-in-loop"]
        assert findings[0].mult == 32
        assert report.gate(findings, {}) == 1

    def test_compiled_callback_flagged(self):
        from jax.experimental import io_callback

        def host(x):
            return np.asarray(x) + 1

        @jax.jit
        def f(x):
            return io_callback(host, jax.ShapeDtypeStruct(x.shape, x.dtype),
                               x)

        txt = f.lower(jnp.zeros((4,), jnp.float32)).compile().as_text()
        assert "host-transfer" in rules(hlo_lint.lint_hlo_text(txt, "f"))

    def test_callback_in_scan_jaxpr(self):
        from jax.experimental import io_callback

        def host(x):
            return np.asarray(x) + 1

        def f(x):
            def body(i, acc):
                return acc + io_callback(
                    host, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
            return jax.lax.fori_loop(0, 8, body, x)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
        assert "callback-in-loop" in rules(hlo_lint.lint_jaxpr(jaxpr, "f"))

    def test_upcast_f32_literal(self):
        """Seeded regression: an f32 constant dragging a bf16 value into
        f32 is flagged; a weak Python float (which stays bf16, emitting no
        convert) is not."""
        def bad(x):
            return x.astype(jnp.float32) * np.float32(1.5)

        def ok(x):
            return x * 1.5

        x = jnp.zeros((4,), jnp.bfloat16)
        findings = hlo_lint.lint_jaxpr(jax.make_jaxpr(bad)(x), "bad")
        assert rules(findings) == ["upcast-f32-literal"]
        assert report.gate(findings, {}) == 1
        assert hlo_lint.lint_jaxpr(jax.make_jaxpr(ok)(x), "ok") == []

    def test_donation_honored_vs_missed(self):
        """Seeded regression: a donated-but-unusable input (output shape
        differs) drops out of input_output_alias and is flagged."""
        @functools.partial(jax.jit, donate_argnums=(0,))
        def good(x):
            return x + 1

        txt = good.lower(jnp.zeros((128,), jnp.float32)).compile().as_text()
        assert hlo_lint.donation_findings(txt, "good", 1) == []

        @functools.partial(jax.jit, donate_argnums=(0,))
        def wasted(x):
            return x[:32] * 2.0

        txt = wasted.lower(jnp.zeros((128,),
                                     jnp.float32)).compile().as_text()
        findings = hlo_lint.donation_findings(txt, "wasted", 1)
        assert rules(findings) == ["donation-miss"]
        assert report.gate(findings, {}) == 1

    def test_recompile_budget(self):
        assert hlo_lint.recompile_findings({"decode": 1}, {"decode": 1},
                                           "s") == []
        findings = hlo_lint.recompile_findings({"decode": 3}, {"decode": 1},
                                               "s")
        assert rules(findings) == ["recompile-budget"]
        # graphs without a declared bound are skipped, not flagged
        assert hlo_lint.recompile_findings({"prefill": 9}, {}, "s") == []


# ---------------------------------------------------------------------------
# Baseline gate + CLI
# ---------------------------------------------------------------------------

class TestBaselineGate:
    def _f(self, rule="r", where="w"):
        return Finding("ast", rule, where, "msg")

    def test_new_fails_baselined_passes_stale_reported(self):
        f = self._f()
        assert report.gate([f], {}) == 1
        assert report.gate([f], {f.key: "known"}) == 0
        new, stale = report.diff([f], {f.key: "known", "ast:r:gone": "old"})
        assert new == [] and stale == ["ast:r:gone"]
        assert report.gate([f], {f.key: "known", "ast:r:gone": "old"}) == 0

    def test_save_load_roundtrip_keeps_justifications(self, tmp_path):
        path = str(tmp_path / "b.json")
        f1, f2 = self._f(where="w1"), self._f(where="w2")
        report.save_baseline([f1], path)
        bl = report.load_baseline(path)
        assert bl == {f1.key: "TODO: justify"}
        bl[f1.key] = "because"
        report.save_baseline([f1, f2], path, old=bl)
        bl2 = report.load_baseline(path)
        assert bl2[f1.key] == "because"
        assert bl2[f2.key] == "TODO: justify"

    def test_version_check(self, tmp_path):
        path = tmp_path / "b.json"
        path.write_text('{"version": 99, "findings": {}}')
        with pytest.raises(ValueError):
            report.load_baseline(str(path))

    def test_missing_baseline_is_empty(self, tmp_path):
        assert report.load_baseline(str(tmp_path / "nope.json")) == {}

    def test_committed_baseline_loads_and_is_justified(self):
        bl = report.load_baseline()
        for key, justification in bl.items():
            assert justification and "TODO" not in justification, key

    def test_cli_gate_and_update(self, tmp_path, capsys):
        from repro.analysis.__main__ import main
        fix = tmp_path / "bad.py"
        fix.write_text(OLD_PROPOSE)
        bl = str(tmp_path / "baseline.json")
        rep = str(tmp_path / "report.json")
        assert main(["--ast", str(fix), "--baseline", bl,
                     "--json", rep]) == 1
        data = json.loads(open(rep).read())
        assert data["n_new"] >= 1 and data["n_findings"] == data["n_new"]
        assert any("host-sync" in k for k in data["new"])
        assert main(["--ast", str(fix), "--baseline", bl,
                     "--update-baseline"]) == 0
        assert main(["--ast", str(fix), "--baseline", bl]) == 0
        out = capsys.readouterr().out
        assert "baselined finding" in out
