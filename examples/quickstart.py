"""Quickstart: fine-tune a small decoder LM with FourierFT in ~30 lines of
public API, then merge the adapter for zero-latency serving.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

import repro.configs as configs
from repro.configs.base import PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.serve import Engine
from repro.train import loop, step as train_step


def main():
    # 1. pick an architecture config (any of the 10 registered archs works;
    #    `reduced` shrinks it to laptop scale for this demo)
    cfg = configs.reduced(configs.get("yi-6b"), layers=4, width=128).replace(
        vocab=256)

    # 2. attach the paper's technique: n spectral coefficients per q/v matrix.
    #    kernel_backend picks how ΔW materializes (DESIGN §Kernels): "auto"
    #    compiles the Pallas kernels on TPU and uses the einsum reference
    #    elsewhere; explain_kernels() shows what each site resolved to.
    peft = PEFTConfig(method="fourierft", n=128, alpha=20.0, train_head=True,
                      kernel_backend="auto")
    model = build(cfg, peft)
    print(f"arch={cfg.name}  trainable params={model.trainable_params():,} "
          f"(vs {sum(x.size for x in jax.tree.leaves(model.init(jax.random.PRNGKey(0))['base'])):,} frozen)")
    print(model.explain_kernels())

    # 3. train with the fault-tolerant loop (async checkpoints, anomaly guard)
    tcfg = TrainConfig(learning_rate=5e-2, total_steps=200, warmup_steps=10)
    state, frozen = train_step.init_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(train_step.make_train_step(model, tcfg))
    data = SyntheticLM(vocab=cfg.vocab, batch=16, seq=64, task_seed=5)
    # (markov teacher => loss floor ~= teacher entropy; adapters+head close
    #  most of the gap from the random-base starting point)
    state, report = loop.run(step_fn, state, frozen, data, tcfg,
                             ckpt_dir="/tmp/repro_quickstart", ckpt_every=50)
    print(f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f} "
          f"({report.steps_run} steps, {report.anomalies} anomalies)")

    # 4. merge ΔW into the base weights and serve (paper §3.1: no latency)
    params = train_step.join_params(model, state["trainable"], frozen)
    engine = Engine(model, params, batch_slots=2, max_len=96)
    outs = engine.generate([jax.numpy.arange(8, dtype=jax.numpy.int32),
                            jax.numpy.arange(4, dtype=jax.numpy.int32)],
                           max_new=12)
    print("generated:", [o.tolist() for o in outs])


if __name__ == "__main__":
    main()
