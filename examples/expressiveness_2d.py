"""Paper Appendix C.2 (Figure 7), reproduced end-to-end: 8-class 2-D
Gaussian-blob classification through a single 64x64 hidden layer, adapting it
with LoRA r=1 vs FourierFT n=128 — EQUAL trainable parameter count (128).

The paper's claim: LoRA r=1 hits an expressiveness bottleneck (never reaches
100% within 2000 epochs) while FourierFT reaches 100% quickly.

    PYTHONPATH=src python examples/expressiveness_2d.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fourierft, lora
from repro.data import SyntheticClassification


D = 64


def make_base(key):
    ks = jax.random.split(key, 4)
    return {
        "w_in": jax.random.normal(ks[0], (2, D)) * 0.5,
        "b_in": jnp.zeros(D),
        "w_hid": jax.random.normal(ks[1], (D, D)) * 0.2,   # the adapted layer
        "b_hid": jnp.zeros(D),
        "w_out": jax.random.normal(ks[2], (D, 8)) * 0.3,
        "b_out": jnp.zeros(8),
    }


def forward(base, delta_fn, x):
    h = jax.nn.relu(x @ base["w_in"] + base["b_in"])
    h = jax.nn.relu(h @ (base["w_hid"] + delta_fn()) + base["b_hid"])
    return h @ base["w_out"] + base["b_out"]


def train(method: str, epochs: int = 2000, lr: float = 0.1, seed: int = 0):
    x, y = SyntheticClassification(num_classes=8, dim=2, noise=0.22,
                                   seed=3).dataset(64)
    base = make_base(jax.random.PRNGKey(seed))
    if method == "fourierft":
        entries = fourierft.sample_entries(D, D, 128, seed=2024)
        train_p = {"c": jnp.zeros(128)}
        delta = lambda p: fourierft.materialize_delta(
            p["c"], entries, D, D, alpha=float(D * D))
    else:  # lora r=1 -> 2*64 = 128 params, equal budget
        train_p = lora.init_lora(jax.random.PRNGKey(seed + 1), D, D, 1)
        delta = lambda p: lora.lora_delta(p["lora_a"], p["lora_b"], 2.0, 1)

    def loss_fn(p):
        logits = forward(base, lambda: delta(p), x)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                 * jax.nn.one_hot(y, 8), -1))

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    @jax.jit
    def acc_fn(p):
        return (jnp.argmax(forward(base, lambda: delta(p), x), -1) == y).mean()

    hist = []
    first_100 = None
    for e in range(epochs):
        train_p, l = step(train_p)
        if e % 50 == 0 or e == epochs - 1:
            acc = float(acc_fn(train_p))
            hist.append((e, float(l), acc))
            if first_100 is None and acc >= 0.999:
                first_100 = e
    n_params = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(train_p))
    return hist, first_100, n_params


def main():
    for method in ["lora", "fourierft"]:
        hist, first_100, n_params = train(method)
        final = hist[-1]
        print(f"\n== {method} ({n_params} trainable params) ==")
        for e, l, a in hist[::4] + [final]:
            print(f"  epoch {e:5d}  loss {l:.4f}  acc {a:.3f}")
        print(f"  reached 100% at epoch: {first_100}")
    print("\nPaper claim (App. C.2): FourierFT overcomes the equal-budget "
          "LoRA bottleneck — compare the two 'reached 100%' lines above.")


if __name__ == "__main__":
    main()
