"""Serving example (deliverable b): merged-adapter batched decoding.

Loads (or trains) FourierFT adapters for a small LM, merges ΔW into the base
weights (zero added inference latency — paper §3.1), and serves a batch of
prompts with greedy decoding through the slot-based engine. Also demonstrates
that many adapters can be stored cheaply and hot-swapped: three "customers"
fine-tuned on different tasks share one base model.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.serve import Engine, merge_for_serving
from repro.train import step as train_step


def train_adapter(model, frozen, task_seed: int, steps: int = 40):
    tcfg = TrainConfig(learning_rate=2e-2, total_steps=steps, warmup_steps=4)
    state, f0 = train_step.init_state(model, tcfg, jax.random.PRNGKey(task_seed))
    frozen = {"base": frozen["base"], "peft": f0["peft"]}
    step_fn = jax.jit(train_step.make_train_step(model, tcfg))
    data = SyntheticLM(vocab=model.cfg.vocab, batch=8, seq=32,
                      task_seed=task_seed)
    for i in range(steps):
        state, m = step_fn(state, frozen, data.batch_at(i))
    return state["trainable"]["peft"], float(m["loss"])


def main():
    cfg = configs.reduced(configs.get("yi-6b"), layers=4, width=128).replace(
        vocab=256)
    peft = PEFTConfig(method="fourierft", n=64, alpha=20.0)
    model = build(cfg, peft)
    params0 = model.init(jax.random.PRNGKey(0))
    frozen = {"base": params0["base"], "peft": {}}

    # three customers, three adapters — each ~64*L*2 floats of storage
    adapters = {}
    for task in (11, 22, 33):
        ad, loss = train_adapter(model, frozen, task)
        n_bytes = sum(v.size * 4 for d in ad.values() for k, v in d.items()
                      if k == "c")
        adapters[task] = ad
        print(f"adapter for task {task}: final loss {loss:.3f}, "
              f"{n_bytes/1024:.1f} KiB checkpoint")

    prompts = [jnp.arange(6, dtype=jnp.int32),
               jnp.arange(3, dtype=jnp.int32) + 7,
               jnp.array([1, 2, 3, 5, 8, 13], jnp.int32)]
    for task, ad in adapters.items():
        params = {"base": params0["base"], "peft": ad}
        t0 = time.perf_counter()
        engine = Engine(model, params, batch_slots=len(prompts), max_len=64)
        outs = engine.generate(prompts, max_new=8)
        dt = time.perf_counter() - t0
        print(f"task {task}: served {len(prompts)} prompts in {dt:.2f}s "
              f"(merged; per-token graph identical to the base model)")
        for i, o in enumerate(outs):
            print(f"  prompt {i}: {o.tolist()}")


if __name__ == "__main__":
    main()
