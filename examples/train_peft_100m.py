"""End-to-end driver (deliverable b): instruction-tuning-style fine-tune of a
~100M-parameter LLaMA-shaped decoder with FourierFT — the paper's Table 4
setting at laptop scale. Pre-trains the base on task A, fine-tunes adapters
on task B, with checkpointing/resume and a LoRA comparison at the paper's
parameter ratio.

    PYTHONPATH=src python examples/train_peft_100m.py --steps 200
(defaults to a quick 40-step run; --steps 300+ reproduces the full curves)
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.configs.base import ModelConfig, PEFTConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build
from repro.train import loop, step as train_step

# ~100M params: 12L, d=768, llama-style (gated mlp, GQA 12/4)
CFG_100M = ModelConfig(
    name="llama-100m", family="dense", num_layers=12, d_model=768,
    n_heads=12, n_kv=4, head_dim=64, d_ff=2048, vocab=8192,
)


def run(method: str, steps: int, pretrained_base, data,
        kernel_backend: str = "auto"):
    peft = (PEFTConfig(method="fourierft", n=256, alpha=16.0,
                       kernel_backend=kernel_backend)
            if method == "fourierft"
            else PEFTConfig(method="lora", lora_r=8, lora_alpha=16.0,
                            kernel_backend=kernel_backend))
    model = build(CFG_100M, peft)
    # which kernel backend each adapted site's ΔW path resolved to
    # (compiled Pallas on TPU, einsum reference elsewhere — DESIGN §Kernels)
    print(model.explain_kernels())
    tcfg = TrainConfig(learning_rate=3e-3 if method == "lora" else 1e-2,
                       total_steps=steps, warmup_steps=max(steps // 10, 2))
    state, frozen = train_step.init_state(model, tcfg, jax.random.PRNGKey(1))
    frozen = {"base": pretrained_base, "peft": frozen["peft"]}
    step_fn = jax.jit(train_step.make_train_step(model, tcfg))
    t0 = time.time()
    state, report = loop.run(
        step_fn, state, frozen, data, tcfg,
        ckpt_dir=f"/tmp/repro_100m_{method}", ckpt_every=max(steps // 2, 10),
        log_every=max(steps // 8, 1))
    return {
        "trainable": model.trainable_params(),
        "first": report.losses[0], "final": report.final_loss,
        "wall_s": time.time() - t0, "anomalies": report.anomalies,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--pretrain-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--kernel-backend", type=str, default="auto",
                    choices=["auto", "pallas", "interpret", "einsum"],
                    help="ΔW kernel policy (DESIGN §Kernels)")
    args = ap.parse_args()

    n_base = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        jax.eval_shape(lambda: build(CFG_100M, PEFTConfig(method="none"))
                       .init(jax.random.PRNGKey(0)))["base"]))
    print(f"base model: {n_base/1e6:.1f}M params")

    # pre-train the base briefly on the "pretraining" task
    base_model = build(CFG_100M, PEFTConfig(method="full"))
    btcfg = TrainConfig(learning_rate=3e-3, total_steps=args.pretrain_steps,
                        warmup_steps=5)
    bstate, bfrozen = train_step.init_state(base_model, btcfg,
                                            jax.random.PRNGKey(0))
    bstep = jax.jit(train_step.make_train_step(base_model, btcfg))
    pre = SyntheticLM(vocab=CFG_100M.vocab, batch=args.batch, seq=args.seq,
                      task_seed=1)
    print(f"pre-training base for {args.pretrain_steps} steps ...")
    for i in range(args.pretrain_steps):
        bstate, m = bstep(bstate, bfrozen, pre.batch_at(i))
    pretrained = bstate["trainable"]["base"]
    print(f"  pretrain loss -> {float(m['loss']):.3f}")

    # fine-tune on the downstream task with each method
    ft_data = SyntheticLM(vocab=CFG_100M.vocab, batch=args.batch,
                          seq=args.seq, seed=2, task_seed=42)
    results = {}
    for method in ["fourierft", "lora"]:
        print(f"\n== fine-tuning with {method} ==")
        results[method] = run(method, args.steps, pretrained, ft_data,
                              kernel_backend=args.kernel_backend)
        r = results[method]
        print(f"  trainable={r['trainable']:,}  loss {r['first']:.3f} -> "
              f"{r['final']:.3f}  ({r['wall_s']:.0f}s, "
              f"anomalies={r['anomalies']})")

    f, l = results["fourierft"], results["lora"]
    print(f"\nFourierFT used {f['trainable']/l['trainable']*100:.1f}% of "
          f"LoRA's parameters; final losses: fourier={f['final']:.3f} "
          f"lora={l['final']:.3f}")


if __name__ == "__main__":
    main()
